"""Serving path: batched prefill + autoregressive decode with a KV cache.

Uses the same `build_prefill_step` / `build_decode_step` builders the
multi-pod dry-run lowers on the production mesh, here executed on the host
mesh with a reduced config — demonstrating that one set of step builders
serves both the dry-run and a real runtime.

    PYTHONPATH=src python examples/serve_batched.py --arch gemma2-2b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ShapeConfig, get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_decode_step, build_prefill_step
from repro.models import init_params, num_params, random_batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=4, help="requests in flight")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path")
    mesh = make_host_mesh(1, 1)
    B, S = args.batch, args.prompt_len
    capacity = S + args.new_tokens

    with jax.set_mesh(mesh):
        params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
        print(f"arch={cfg.name} params={num_params(params)/1e6:.1f}M "
              f"batch={B} prompt={S} new={args.new_tokens}")

        pshape = ShapeConfig("serve_prefill", capacity, B, "prefill")
        jit_p, specs_p = build_prefill_step(cfg, mesh, dtype=jnp.float32)
        sp = specs_p(pshape)
        caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sp["caches"])
        batch = random_batch(jax.random.PRNGKey(1), cfg, B, S, jnp.float32)

        t0 = time.perf_counter()
        logits, caches = jit_p(ShapeConfig("p", S, B, "prefill"))(
            params, batch, caches
        )
        logits.block_until_ready()
        print(f"prefill: {1e3*(time.perf_counter()-t0):.0f} ms "
              f"logits={logits.shape}")

        dshape = ShapeConfig("serve_decode", capacity, B, "decode")
        jit_d, _ = build_decode_step(cfg, mesh, dtype=jnp.float32)
        step = jit_d(dshape)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out_tokens = [tok]
        t0 = time.perf_counter()
        for i in range(args.new_tokens - 1):
            logits, caches = step(params, caches, tok, jnp.int32(S + i))
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out_tokens.append(tok)
        tok.block_until_ready()
        dt = time.perf_counter() - t0
        print(f"decode: {args.new_tokens-1} steps x {B} requests in "
              f"{1e3*dt:.0f} ms ({1e3*dt/(args.new_tokens-1):.1f} ms/token)")
        seq = jnp.concatenate(out_tokens, axis=1)
        print("generated token ids (request 0):", seq[0, :16].tolist(), "...")


if __name__ == "__main__":
    main()
