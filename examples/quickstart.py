"""Quickstart: the paper in 60 seconds.

Solves the Section-5.1 federated quadratic minimax game with one round
engine and six communication strategies — centralized GDA (FullSync),
Local SGDA (LocalOnly), FedGDA-GT (GradientTracking, this paper), plus
the scenario-opening variants: client sampling (PartialParticipation),
sparsified corrections with error feedback (CompressedGT), and QSGD-style
stochastically quantized corrections (QuantizedGT) — and prints the
optimality gap every few hundred rounds.  FedGDA-GT is the only one that
is simultaneously accurate (exact limit) and cheap (K local steps per
communication round).

Compression knobs (CompressedGT / QuantizedGT):
  compression_ratio / ratio  kept fraction of correction entries per
                             round (1.0 = dense); `mode` picks "topk"
                             (largest magnitude) or "randk" (uniform)
  bits                       QuantizedGT only: stochastic-quantization
                             bit-width for the kept entries, per-agent
                             max-abs scale, unbiased rounding (>= 32
                             disables; bits=32 + ratio=1.0 IS FedGDA-GT)
  error_feedback             accumulate what compression dropped and
                             re-inject it next round (tightens the floor)
  use_kernel                 dispatch lane-aligned leaves to the fused
                             Pallas compress-correction kernel
                             (kernels/compress_correction.py); pairs
                             with kernel_interpret — True (default) runs
                             the CPU interpreter for validation, set
                             False on real TPU for the compiled kernel

Two finales: FedGDA-GT once more on the ASYNC runtime
(`fed.async_runtime.AsyncFederatedRunner`): the same four round phases
(broadcast / exchange_corrections / local_steps / aggregate — see
`repro.core.engine.make_phases`) dispatched per agent shard on separate
emulated devices, with the exchange server-side and broadcasts
double-buffered — same answer to fp tolerance, overlapped schedule.
Then an ELASTIC run (`repro.sim`): the same game under a flaky Markov
join/leave population, where FedGDA-GT with membership-aware tracker
rebasing still converges to the exact minimax point while Local SGDA
under the identical churn stalls at its bias floor.

    PYTHONPATH=src python examples/quickstart.py
"""
import os

# 8 emulated host devices so the async finale has shards to land on
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8"
    ).strip()

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from repro.core import make_round, run_strategy_rounds, tree_sq_dist
from repro.fed import (
    CompressedGT,
    FullSync,
    GradientTracking,
    LocalOnly,
    PartialParticipation,
    QuantizedGT,
)
from repro.problems import make_quadratic_problem, quadratic_minimax_point


def main() -> None:
    # 20 heterogeneous agents, d = 50 (the paper's own setup)
    prob = make_quadratic_problem(
        jax.random.PRNGKey(0), dim=50, num_samples=500, num_agents=20
    )
    x_star, y_star = quadratic_minimax_point(prob)

    def gap(x, y):
        return {"gap": tree_sq_dist(x, x_star) + tree_sq_dist(y, y_star)}

    K, eta, T = 20, 1e-4, 2000
    strategies = {
        "centralized GDA   (communicates every step)": (FullSync(), 1),
        "Local SGDA  K=20  (biased fixed point)": (LocalOnly(), K),
        "FedGDA-GT   K=20  (this paper)": (GradientTracking(), K),
        "FedGDA-GT   K=20  50% client sampling": (
            PartialParticipation(participation=0.5, seed=0), K,
        ),
        # wire_transport: the corrections are really encoded as packed
        # (value, index, scale) payloads and decoded server-side — same
        # iterates bit for bit, payload bytes matching bytes_per_round
        "FedGDA-GT   K=20  top-10% corrections + error feedback": (
            CompressedGT(
                compression_ratio=0.1, mode="topk", wire_transport=True
            ), K,
        ),
        "FedGDA-GT   K=20  8-bit quantized corrections (unbiased + EF)": (
            QuantizedGT(bits=8, seed=0, wire_transport=True), K,
        ),
    }
    x0 = jnp.zeros(50)
    print(f"rounds={T}  local steps K={K}  eta={eta}\n")
    m = jax.tree.leaves(prob.agent_data)[0].shape[0]
    for name, (strategy, k) in strategies.items():
        # explicit_state works for stateless strategies too (state is {}),
        # so one code path serves all five
        rnd = make_round(prob.loss, strategy, k, eta, explicit_state=True)
        state0 = strategy.init_state(x0, x0, m)
        (_, _, _), mtr = run_strategy_rounds(
            jax.jit(rnd), x0, x0, prob.agent_data, T, state0, gap
        )
        g = mtr["gap"]
        marks = "  ".join(
            f"t={t}: {float(g[t]):.1e}" for t in (0, 100, 500, 1000, T - 1)
        )
        print(f"{name}\n  {marks}\n")

    # the async runtime: same phases, per-agent-shard dispatch
    from repro.fed import AsyncFederatedRunner, FederatedRunner

    runner = AsyncFederatedRunner(
        prob.loss, GradientTracking(), prob.agent_data, K, eta,
        metric_fn=gap,
    )
    xa, ya = runner.run(x0, x0, 500)
    print(
        f"FedGDA-GT on the async runtime ({runner._n_shards} agent shards"
        f" over {len(jax.devices())} devices)\n"
        f"  t=500: {runner.metric_series('gap')[-1]:.1e}"
        " (matches the sync runner to fp tolerance)\n"
    )

    # the elastic finale: a FLAKY population (repro.sim) — agents join
    # and leave between rounds per a seeded Markov churn process.  The
    # membership-aware elastic round re-normalizes the server weights
    # over each round's active set and keeps a per-agent tracker table
    # (absent agents stand in with their last anchor gradient; rejoining
    # agents re-anchor at the current iterate within one round), so
    # FedGDA-GT KEEPS its exact limit under churn; Local SGDA under the
    # very same churn stays pinned at its bias floor.
    from repro.sim import make_population

    schedule = make_population("flaky", m).schedule(0, T, K)
    print(
        f"flaky population: {schedule.participation_rate():.0%} mean "
        f"participation, {schedule.churn_events()} churn events in {T} rounds"
    )
    for name, strategy in (
        ("FedGDA-GT   K=20  + tracker rebase", GradientTracking()),
        ("Local SGDA  K=20  (same churn)", LocalOnly()),
    ):
        er = FederatedRunner.from_strategy(
            prob.loss, strategy, prob.agent_data, K, eta, metric_fn=gap
        )
        er.run(x0, x0, T, schedule=schedule)
        g = er.metric_series("gap")
        marks = "  ".join(
            f"t={t}: {float(g[t]):.1e}" for t in (0, 100, 500, 1000, T - 1)
        )
        print(f"{name}\n  {marks}\n")

    print("FedGDA-GT converges linearly to the EXACT minimax point with a")
    print("constant stepsize — even under join/leave churn, thanks to the")
    print("membership-aware tracker rebase; Local SGDA plateaus at its bias")
    print("floor; client sampling and compressed corrections trade a small")
    print("accuracy floor for less communication (the unbiased 8-bit")
    print("quantizer's floor is the tightest); centralized GDA matches")
    print("FedGDA-GT's limit but needs K x more communication rounds")
    print("(Theorem 1).")


if __name__ == "__main__":
    main()
