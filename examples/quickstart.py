"""Quickstart: the paper in 60 seconds.

Solves the Section-5.1 federated quadratic minimax game with the three
algorithms the paper compares — centralized GDA, Local SGDA and FedGDA-GT —
and prints the optimality gap every few hundred rounds.  FedGDA-GT is the
only one that is simultaneously accurate (exact limit) and cheap
(K local steps per communication round).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from repro.core import (
    make_fedgda_gt_round,
    make_local_sgda_round,
    run_rounds,
    tree_sq_dist,
)
from repro.problems import make_quadratic_problem, quadratic_minimax_point


def main() -> None:
    # 20 heterogeneous agents, d = 50 (the paper's own setup)
    prob = make_quadratic_problem(
        jax.random.PRNGKey(0), dim=50, num_samples=500, num_agents=20
    )
    x_star, y_star = quadratic_minimax_point(prob)

    def gap(x, y):
        return {"gap": tree_sq_dist(x, x_star) + tree_sq_dist(y, y_star)}

    K, eta, T = 20, 1e-4, 2000
    algos = {
        "centralized GDA   (communicates every step)":
            make_local_sgda_round(prob.loss, 1, eta, eta),
        "Local SGDA  K=20  (biased fixed point)":
            make_local_sgda_round(prob.loss, K, eta, eta),
        "FedGDA-GT   K=20  (this paper)":
            make_fedgda_gt_round(prob.loss, K, eta),
    }
    x0 = jnp.zeros(50)
    print(f"rounds={T}  local steps K={K}  eta={eta}\n")
    for name, rnd in algos.items():
        (_, _), m = run_rounds(jax.jit(rnd), x0, x0, prob.agent_data, T, gap)
        g = m["gap"]
        marks = "  ".join(
            f"t={t}: {float(g[t]):.1e}" for t in (0, 100, 500, 1000, T - 1)
        )
        print(f"{name}\n  {marks}\n")
    print("FedGDA-GT converges linearly to the EXACT minimax point with a")
    print("constant stepsize; Local SGDA plateaus at its bias floor;")
    print("centralized GDA matches FedGDA-GT's limit but needs K x more")
    print("communication rounds (Theorem 1).")


if __name__ == "__main__":
    main()
